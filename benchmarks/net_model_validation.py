"""Network-model validation: simulated claim cost vs measured claim cost.

Calibrates one ``NetworkModel`` per claim substrate from the committed
measurement snapshots —

* ``BENCH_source_overhead.json`` — shared-memory fetch-and-add
  (``shared_static_ns_per_claim_4procs``) and local foreman round-trip
  (``foreman_ns_per_claim_4procs``), the ``placement="process"`` substrates;
* ``BENCH_dist_scaling.json`` — TCP remote-counter DCA, network-foreman CCA
  and the node-master tree at 4 workers, the ``placement="net"`` substrates

— then runs the *simulators* under each calibrated model and checks that the
per-claim cost the simulation charges lands within 2x of the measurement it
was calibrated against (the plumbing check: legs must be charged once, on
the right timeline, not double-counted or dropped).  The second half replays
the paper's ordering claim under the two network perturbation families
(``latency_spike``, ``slow_link``): the simulators must predict DCA <= CCA
loop time, and a real process-placement run of both approaches under the
same scenario must agree.

Headline booleans (gated by CI via check_regression.py --require-true):

* ``within_2x_all_sources``       — every substrate's sim/measured ratio in [0.5, 2].
* ``sim_dca_le_cca_latency_spike`` / ``sim_dca_le_cca_slow_link``
* ``real_matches_sim_ordering``   — the real executor runs agree with the sim.

Run:  PYTHONPATH=src python benchmarks/net_model_validation.py \
          [--no-real] [--json out.json]
"""

import argparse
import json
import os
import platform
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

from repro.core.simulator import SimConfig, simulate
from repro.core.fastsim import simulate_fast
from repro.core.source import ScheduleSpec, make_source
from repro.core.techniques import DLSParams
from repro.select.scenarios import (
    NetworkModel,
    PerturbationScenario,
)

# one chunk's compute dwarfs any modeled claim cost -> no coordinator
# queueing, so the sim's marginal cost per claim is the claim cost itself
_N, _P, _MIN_CHUNK, _ITER_S = 2000, 4, 50, 1e-3


def _claims_per_s(doc: dict, transport: str, workers: int = 4) -> float:
    for row in doc["claims"]:
        if row["transport"] == transport and row["workers"] == workers:
            return float(row["claims_per_s"])
    raise KeyError(f"no {transport} w{workers} row in BENCH_dist_scaling.json")


def calibrate(overhead: dict, scaling: dict) -> dict:
    """Measured per-claim round-trip seconds per substrate, and the
    NetworkModel whose claim cost reproduces it (splits are even: the
    within-2x check binds the total per-claim charge, not the leg split)."""
    shared_rt = overhead["shared_static_ns_per_claim_4procs"] / 1e9
    foreman_rt = overhead["foreman_ns_per_claim_4procs"] / 1e9
    # sleep-bound claim loops: each of W workers claims serially, so the
    # per-worker round trip is W / aggregate claims/s
    net_dca_rt = 4.0 / _claims_per_s(scaling, "dca")
    net_cca_rt = 4.0 / _claims_per_s(scaling, "cca")
    tree_rt = 4.0 / _claims_per_s(scaling, "tree")
    batch = 16
    return {
        "shared_static": {
            "measured_s": shared_rt,
            "model": NetworkModel(rma_oneway_s=shared_rt / 2.0),
            "approach": "dca",
        },
        "foreman": {
            "measured_s": foreman_rt,
            # claim cost 2*ser + 2*prop == the measured round trip
            "model": NetworkModel(serialization_s=foreman_rt / 4.0,
                                  propagation_s=foreman_rt / 4.0),
            "approach": "cca",
        },
        "net_dca": {
            "measured_s": net_dca_rt,
            "model": NetworkModel(rma_oneway_s=net_dca_rt / 2.0),
            "approach": "dca",
        },
        "net_cca": {
            "measured_s": net_cca_rt,
            "model": NetworkModel(serialization_s=net_cca_rt / 4.0,
                                  propagation_s=net_cca_rt / 4.0),
            "approach": "cca",
        },
        "tree": {
            "measured_s": tree_rt,
            "model": NetworkModel(batch_refill_s=tree_rt * batch,
                                  batch_chunks=batch),
            "approach": "tree",
        },
    }


def sim_per_claim_s(model: NetworkModel, approach: str) -> float:
    """Marginal simulated cost per claim: T(network) - T(no network),
    normalized to one claim on one PE — through the real engines, not the
    model's own arithmetic."""
    params = DLSParams(N=_N, P=_P, min_chunk=_MIN_CHUNK)
    costs = np.full(_N, _ITER_S)
    scen = PerturbationScenario.constant(_P, name="calib").with_network(model)
    if approach == "tree":
        # the amortized substrate: a two-level hierarchical source (global
        # board + per-group local queues), priced by the event engine
        spec = ScheduleSpec("ss", _N, _P, mode="dca", min_chunk=_MIN_CHUNK,
                            levels=(("ss", 2), ("ss", 2)))
        cfg = SimConfig("ss", params, approach="dca")
        base = simulate(cfg, costs, source=make_source(spec))
        res = simulate(cfg, costs, source=make_source(spec), scenario=scen)
    else:
        # the measured CCA substrates run a *dedicated* coordinator process
        # (foreman / chunk server), so calibrate against the dedicated-master
        # sim — non-dedicated would also charge PE0 the displacement
        cfg = SimConfig("ss", params, approach=approach,
                        dedicated_master=(approach == "cca"))
        base = simulate_fast(cfg, costs)
        res = simulate_fast(cfg, costs, scenario=scen)
    n_claims = int(res.num_chunks)
    return (res.t_parallel - base.t_parallel) * _P / n_claims


def ordering_scenarios(model: NetworkModel):
    from repro.select.scenarios import PerturbationScenario as PS

    horizon = _N * _ITER_S / _P
    return {
        "latency_spike": PS.latency_spike(
            _P, pes=(0,), windows=[(0.2 * horizon, 0.7 * horizon)],
            factor=8.0, network=model,
        ),
        "slow_link": PS.slow_link(_P, slow_pes=(_P - 1,), factor=4.0,
                                  network=model),
    }


def sim_ordering(model: NetworkModel) -> dict:
    params = DLSParams(N=_N, P=_P, min_chunk=_MIN_CHUNK)
    costs = np.full(_N, _ITER_S)
    out = {}
    for name, scen in ordering_scenarios(model).items():
        t = {}
        for approach in ("dca", "cca"):
            cfg = SimConfig("ss", params, approach=approach)
            t[approach] = simulate_fast(cfg, costs, scenario=scen).t_parallel
        out[name] = {
            "sim_t_dca_s": t["dca"],
            "sim_t_cca_s": t["cca"],
            "sim_dca_le_cca": bool(t["dca"] <= t["cca"]),
        }
    return out


def _sleep_fn(iter_s):
    import functools

    return functools.partial(_sleep_range, iter_s)


def _sleep_range(iter_s, lo, hi):
    time.sleep((hi - lo) * iter_s)


def real_ordering(model: NetworkModel, rows: dict) -> None:
    """Process-placement executors under the same scenarios: does the real
    DCA <= CCA ordering match the sim's prediction?  (The foreman already
    costs a real IPC round trip; the injected model rides on top for both
    approaches identically, so the comparison stays fair.)"""
    from repro.dist.executor import DistributedExecutor

    # smaller N than the sim: real sleeps, and CCA serializes its claims
    n, iter_s, min_chunk = 400, 2e-4, 4
    params = DLSParams(N=n, P=_P, min_chunk=min_chunk)
    fn = _sleep_fn(iter_s)
    for name, scen in ordering_scenarios(model).items():
        walls = {}
        for mode in ("dca", "cca"):
            scen_n = scen.with_network(model)
            ex = DistributedExecutor("ss", params, mode, scenario=scen_n)
            try:
                walls[mode] = ex.run(fn, _P, join_timeout=120)
            finally:
                ex.close()
        rows[name]["real_wall_dca_s"] = walls["dca"]
        rows[name]["real_wall_cca_s"] = walls["cca"]
        rows[name]["real_dca_le_cca"] = bool(walls["dca"] <= walls["cca"])
        rows[name]["real_matches_sim"] = (
            rows[name]["real_dca_le_cca"] == rows[name]["sim_dca_le_cca"]
        )


def bench(run_real: bool = True) -> dict:
    with open(os.path.join(_ROOT, "BENCH_source_overhead.json")) as f:
        overhead = json.load(f)
    with open(os.path.join(_ROOT, "BENCH_dist_scaling.json")) as f:
        scaling = json.load(f)
    cal = calibrate(overhead, scaling)
    calibration = {}
    for kind, row in cal.items():
        sim_s = sim_per_claim_s(row["model"], row["approach"])
        ratio = sim_s / row["measured_s"]
        calibration[kind] = {
            "name": kind,
            "measured_per_claim_s": row["measured_s"],
            "sim_per_claim_s": sim_s,
            "ratio": ratio,
            "within_2x": bool(0.5 <= ratio <= 2.0),
        }
    # the ordering claim uses the process-placement calibration (the real
    # replay below runs process executors)
    ordering = sim_ordering(cal["foreman"]["model"])
    if run_real:
        real_ordering(cal["foreman"]["model"], ordering)
    headline = {
        "within_2x_all_sources": all(r["within_2x"] for r in calibration.values()),
        "sim_dca_le_cca_latency_spike": ordering["latency_spike"]["sim_dca_le_cca"],
        "sim_dca_le_cca_slow_link": ordering["slow_link"]["sim_dca_le_cca"],
    }
    if run_real:
        headline["real_matches_sim_ordering"] = all(
            r["real_matches_sim"] for r in ordering.values()
        )
    return {
        "meta": {
            "bench": "net_model_validation",
            "platform": platform.platform(),
            "python": platform.python_version(),
            "sim_N": _N,
            "sim_P": _P,
            "min_chunk": _MIN_CHUNK,
            "iter_s": _ITER_S,
            "real_runs": bool(run_real),
        },
        "calibration": [
            {k: (round(v, 9) if isinstance(v, float) else v) for k, v in r.items()}
            for r in calibration.values()
        ],
        "ordering": [
            dict({"name": k}, **{kk: (round(vv, 6) if isinstance(vv, float) else vv)
                                 for kk, vv in r.items()})
            for k, r in ordering.items()
        ],
        "headline": headline,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-real", action="store_true",
                    help="skip the real process-executor ordering replay")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    doc = bench(run_real=not args.no_real)
    print(f"{'substrate':14s} {'measured/claim':>15s} {'sim/claim':>12s} "
          f"{'ratio':>7s}  ok")
    for r in doc["calibration"]:
        print(f"{r['name']:14s} {r['measured_per_claim_s']*1e6:13.1f}us "
              f"{r['sim_per_claim_s']*1e6:10.1f}us {r['ratio']:7.2f}  "
              f"{'OK' if r['within_2x'] else 'FAIL'}")
    for r in doc["ordering"]:
        line = (f"{r['name']:14s} sim dca {r['sim_t_dca_s']:.4f}s vs "
                f"cca {r['sim_t_cca_s']:.4f}s -> "
                f"{'dca<=cca' if r['sim_dca_le_cca'] else 'cca<dca'}")
        if "real_dca_le_cca" in r:
            line += (f" | real dca {r['real_wall_dca_s']:.4f}s vs "
                     f"cca {r['real_wall_cca_s']:.4f}s "
                     f"{'(agrees)' if r['real_matches_sim'] else '(DISAGREES)'}")
        print(line)
    print("headline:", doc["headline"])
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if not all(doc["headline"].values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
