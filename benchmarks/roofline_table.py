"""Aggregate the dry-run JSONs into the §Roofline table (markdown + CSV)."""

from __future__ import annotations

import json
from pathlib import Path

from repro.roofline.terms import HW

COLS = ("arch", "shape", "mesh", "kind", "compute_ms", "memory_ms",
        "collective_ms", "dominant", "mf_ratio", "peak_gb", "fits_hbm")


def load_records(dryrun_dir: str = "experiments/dryrun", include_tagged: bool = False):
    recs = []
    for p in sorted(Path(dryrun_dir).glob("*.json")):
        stem_parts = p.stem.split("_pod")
        tagged = "_" in stem_parts[-1].replace("2x16x16", "").replace("16x16", "").strip("_")
        r = json.loads(p.read_text())
        r["_tagged"] = "_tag_" if tagged else ""
        r["_file"] = p.name
        base = (r["mesh"] in p.stem) and p.stem.endswith(r["mesh"].replace("pod", "pod"))
        r["_is_base"] = p.stem == f'{r["arch"]}_{r["shape"]}_{r["mesh"]}'
        if include_tagged or r["_is_base"]:
            recs.append(r)
    return recs


def row_of(r):
    t = r["roofline"]
    mf = r["analytic"].get("model_flops", 0.0) / max(r["analytic"]["flops_global"], 1.0)
    peak = r["memory"]["peak_bytes"] / 1e9
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"], "kind": r["kind"],
        "compute_ms": t["compute_s"] * 1e3, "memory_ms": t["memory_s"] * 1e3,
        "collective_ms": t["collective_s"] * 1e3, "dominant": t["dominant"].replace("_s", ""),
        "mf_ratio": mf, "peak_gb": peak,
        "fits_hbm": "yes" if peak <= HW["hbm_bytes"] / 1e9 else "NO",
    }


def emit_table(emit, dryrun_dir: str = "experiments/dryrun"):
    for r in load_records(dryrun_dir):
        row = row_of(r)
        emit(
            f'roofline/{row["arch"]}/{row["shape"]}/{row["mesh"]}',
            0.0,
            f'compute_ms={row["compute_ms"]:.3f};memory_ms={row["memory_ms"]:.3f};'
            f'collective_ms={row["collective_ms"]:.3f};dominant={row["dominant"]};'
            f'useful_flops_ratio={row["mf_ratio"]:.3f};peak_gb={row["peak_gb"]:.2f};'
            f'fits={row["fits_hbm"]}',
        )


def markdown_table(dryrun_dir: str = "experiments/dryrun") -> str:
    rows = [row_of(r) for r in load_records(dryrun_dir)]
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    out = ["| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
           "| bound | useful-FLOP ratio | peak GB/dev | fits 16GB |",
           "|---|---|---|---|---|---|---|---|---|---|"[:-4]]
    for r in rows:
        out.append(
            f'| {r["arch"]} | {r["shape"]} | {r["mesh"]} | {r["compute_ms"]:.2f} '
            f'| {r["memory_ms"]:.2f} | {r["collective_ms"]:.2f} | {r["dominant"]} '
            f'| {r["mf_ratio"]:.2f} | {r["peak_gb"]:.2f} | {r["fits_hbm"]} |'
        )
    return "\n".join(out)


if __name__ == "__main__":
    print(markdown_table())
