"""SimAS selection quality across the mixed-perturbation suite.

For every scenario in ``select.scenarios.mixed_suite``: T_loop^par of all
seventeen techniques x {cca, dca} as fixed baselines, next to the online
``SelectingSource`` (scenario estimated purely from claim/report feedback).
The quality numbers (``t_selector``, ``vs_best``, ``vs_worst``) are
deterministic simulation outputs, so the committed snapshot
(BENCH_simas_selection.json) doubles as a CI regression gate input.

Two machine-independent headline booleans ride the gate
(``--require-true`` in ci.yml):

* ``selector_within_5pct_all_scenarios`` — the online selector lands
  within 5% of the best fixed (technique, approach) pair in every
  mixed-suite scenario (the SimAS headline claim);
* ``auto_selects_adaptive_some_scenario`` — in the assignment-overhead
  regime (h_assign_s = 100us, where chunk count is expensive and the
  feedback family's measured weights pay off) the offline ranking picks
  an adaptive technique outright in at least one perturbed scenario —
  i.e. the seventeen-technique portfolio is not a twelve-technique
  portfolio with dead weight.

Run:  PYTHONPATH=src python benchmarks/simas_selection.py [--full] [--json out.json]
"""

import argparse
import json
import os
import platform
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core.simulator import mandelbrot_costs
from repro.core.techniques import DLSParams, get_technique
from repro.select import evaluate_selector, mixed_suite, select_technique

# the assignment-overhead regime for the adaptive headline: at 100us per
# chunk assignment the scheduler is paying real money for every extra
# chunk, and the feedback family's measured per-PE weights start winning
# perturbed scenarios outright (at the default 1us, ss/dca's fine
# granularity is nearly free and dominates)
H_ASSIGN_ADAPTIVE_S = 1e-4


def bench(full: bool = False) -> dict:
    n, p = (16_384, 64) if full else (4_096, 32)
    costs = mandelbrot_costs(n, conversion_threshold=64, mean_s=0.002)
    suite = mixed_suite(p, float(costs.sum()) / p)
    params = DLSParams(N=n, P=p)
    t0 = time.perf_counter()
    rows = evaluate_selector(params, costs, suite)
    adaptive_rows = []
    for scen in suite:
        best = select_technique(params, costs, scen,
                                h_assign_s=H_ASSIGN_ADAPTIVE_S)
        adaptive_rows.append({
            "scenario": scen.name,
            "winner": f"{best['technique']}/{best['effective_approach']}",
            "t_parallel": round(best["t_parallel"], 6),
            "is_adaptive": get_technique(best["technique"]).requires_feedback,
        })
    wall = time.perf_counter() - t0
    return {
        "scale": "full" if full else "ci",
        "platform": platform.platform(),
        "python": platform.python_version(),
        "N": n,
        "P": p,
        "wall_s": round(wall, 3),
        "selector_within_5pct_all_scenarios": all(
            r["vs_best"] <= 1.05 for r in rows
        ),
        "auto_selects_adaptive_some_scenario": any(
            r["is_adaptive"] for r in adaptive_rows
        ),
        "h_assign_adaptive_s": H_ASSIGN_ADAPTIVE_S,
        "adaptive_regime": adaptive_rows,
        "scenarios": [
            {k: (round(v, 6) if isinstance(v, float) else v) for k, v in r.items()}
            for r in rows
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger N/P regime")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    doc = bench(full=args.full)
    hdr = (f"{'scenario':12s} {'selector':>9s} {'best fixed':>16s} "
           f"{'worst fixed':>16s} {'vs_best':>8s} {'vs_worst':>9s}  final")
    print(hdr)
    for r in doc["scenarios"]:
        print(
            f"{r['scenario']:12s} {r['t_selector']:9.4f} "
            f"{r['t_best_fixed']:9.4f} ({r['best_fixed'].split('/')[0]:>5s}) "
            f"{r['t_worst_fixed']:9.4f} ({r['worst_fixed'].split('/')[0]:>5s}) "
            f"{r['vs_best']:8.3f} {r['vs_worst']:9.3f}  {r['final_technique']}"
        )
    print(f"# h_assign={doc['h_assign_adaptive_s']:g}s regime winners: "
          + ", ".join(f"{r['scenario']}={r['winner']}"
                      for r in doc["adaptive_regime"]))
    print(f"# selector_within_5pct_all_scenarios="
          f"{doc['selector_within_5pct_all_scenarios']} "
          f"auto_selects_adaptive_some_scenario="
          f"{doc['auto_selects_adaptive_some_scenario']}")
    print(f"# {len(doc['scenarios'])} scenarios in {doc['wall_s']}s", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
