"""SimAS selection quality across the mixed-perturbation suite.

For every scenario in ``select.scenarios.mixed_suite``: T_loop^par of all
twelve techniques x {cca, dca} as fixed baselines, next to the online
``SelectingSource`` (scenario estimated purely from claim/report feedback).
The quality numbers (``t_selector``, ``vs_best``, ``vs_worst``) are
deterministic simulation outputs, so the committed snapshot
(BENCH_simas_selection.json) doubles as a CI regression gate input.

Run:  PYTHONPATH=src python benchmarks/simas_selection.py [--full] [--json out.json]
"""

import argparse
import json
import os
import platform
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core.simulator import mandelbrot_costs
from repro.core.techniques import DLSParams
from repro.select import evaluate_selector, mixed_suite


def bench(full: bool = False) -> dict:
    n, p = (16_384, 64) if full else (4_096, 32)
    costs = mandelbrot_costs(n, conversion_threshold=64, mean_s=0.002)
    suite = mixed_suite(p, float(costs.sum()) / p)
    t0 = time.perf_counter()
    rows = evaluate_selector(DLSParams(N=n, P=p), costs, suite)
    wall = time.perf_counter() - t0
    return {
        "scale": "full" if full else "ci",
        "platform": platform.platform(),
        "python": platform.python_version(),
        "N": n,
        "P": p,
        "wall_s": round(wall, 3),
        "scenarios": [
            {k: (round(v, 6) if isinstance(v, float) else v) for k, v in r.items()}
            for r in rows
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger N/P regime")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    doc = bench(full=args.full)
    hdr = (f"{'scenario':12s} {'selector':>9s} {'best fixed':>16s} "
           f"{'worst fixed':>16s} {'vs_best':>8s} {'vs_worst':>9s}  final")
    print(hdr)
    for r in doc["scenarios"]:
        print(
            f"{r['scenario']:12s} {r['t_selector']:9.4f} "
            f"{r['t_best_fixed']:9.4f} ({r['best_fixed'].split('/')[0]:>5s}) "
            f"{r['t_worst_fixed']:9.4f} ({r['worst_fixed'].split('/')[0]:>5s}) "
            f"{r['vs_best']:8.3f} {r['vs_worst']:9.3f}  {r['final_technique']}"
        )
    print(f"# {len(doc['scenarios'])} scenarios in {doc['wall_s']}s", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
