"""Distributed scaling: claims/s and makespan vs worker count x transport.

Three network transports from ``repro.net``, swept over worker counts:

* ``dca``  — ``RemoteCounterSource``: one fetch-and-add RPC per claim, chunk
  resolved from local closed-form tables (the paper's RMA fetch-and-add
  DCA, on TCP).  The per-claim chunk-calculation delay is paid
  *concurrently*, in the claimer.
* ``cca``  — ``NetworkForemanSource``: calculate-then-reply round-trip; the
  chunk-calculation delay is serialized inside the foreman's critical
  section (the paper's centralized baseline, on TCP).
* ``tree`` — ``NodeMasterTree`` over 4 simulated nodes: per-node masters
  claim global batches over TCP and re-serve them through shared memory,
  so workers stay off the network on the common claim path.

Two measurements per (transport, worker count) cell:

* **claims/s** — thread claimers draining a fixed-step schedule ("ss",
  ~2000 steps): pure scheduling throughput, the quantity the paper's h/sigma
  overhead model is about.  The headline boolean
  ``dca_beats_cca_all_counts`` asserts the decentralized claim path wins at
  every swept count.
* **makespan_s** — real worker *processes* through ``SimulatedCluster`` /
  ``DistributedExecutor`` with a sleep-bound workload (this host schedules
  sleeps, not FLOPs, so counts up to 64 are honest).  The boolean
  ``tree_sustains_64_workers`` asserts the 4-node tree completes a
  64-worker run with exact coverage.

Wall-clock leaves (``*_s``, ``claims_per_s``) are machine-scheduling time:
the CI gate skips them and checks the deterministic leaves plus the two
booleans via ``check_regression.py --require-true`` (bench-gate job).

Run:  JAX_PLATFORMS=cpu PYTHONPATH=src python benchmarks/dist_scaling.py \
          [--json out.json] [--quick]

The committed snapshot is BENCH_dist_scaling.json.
"""

import argparse
import functools
import json
import os
import platform
import sys
import threading
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core.techniques import DLSParams
from repro.net import NodeMasterTree, SimulatedCluster
from repro.net.sources import _net_source_for

N_NODES = 4
CALC_DELAY_S = 1e-4  # per-chunk calculation cost (serialized under CCA)
CLAIM_STEPS = 2000  # fixed step count per claims/s cell
ITER_S = 1e-4  # makespan workload: sleep-bound per-iteration cost
MAKESPAN_N = 3200
MIN_CHUNK = 4


def _work(per_iter_s, lo, hi):
    time.sleep((hi - lo) * per_iter_s)


# ---------------------------------------------------------------------------
# claims/s: thread claimers against one networked source (or tree board)
# ---------------------------------------------------------------------------


def _drain_threads(claim, n_threads, concurrent_delay_s):
    """Drain ``claim(worker)`` from ``n_threads`` claimers; return
    (chunks, wall_s).  ``concurrent_delay_s`` models the DCA-side chunk
    calculation: each claimer pays it locally, in parallel."""
    counts = [0] * n_threads

    def run(wid):
        while True:
            c = claim(wid)
            if c is None:
                return
            counts[wid] += 1
            if concurrent_delay_s:
                time.sleep(concurrent_delay_s)

    threads = [threading.Thread(target=run, args=(w,)) for w in range(n_threads)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return sum(counts), time.perf_counter() - t0


def _claims_cell(transport, workers):
    n = CLAIM_STEPS * 2  # "ss" with min_chunk=2 -> exactly CLAIM_STEPS steps
    params = DLSParams(N=n, P=workers, min_chunk=2)
    if transport == "cca":
        src = _net_source_for("ss", params, "cca", calc_delay_s=CALC_DELAY_S)
        try:
            served, wall = _drain_threads(src.claim, workers, 0.0)
        finally:
            src.close()
    elif transport == "dca":
        src = _net_source_for("ss", params, "dca")
        try:
            served, wall = _drain_threads(src.claim, workers, CALC_DELAY_S)
        finally:
            src.close()
    else:  # tree: 4 node boards fed by masters, workers claim via shm
        # coarse global batches (fsc, floored at 128 iterations) keep the
        # masters' TCP traffic to a few dozen RPCs; "ss" locally subdivides
        gsrc = _net_source_for(
            "fsc", DLSParams(N=n, P=N_NODES, min_chunk=128), "dca"
        )
        trees = [
            NodeMasterTree(gsrc, node_id=k, local_workers=max(workers // N_NODES, 1),
                           local_technique="ss", min_chunk=2, N=n)
            for k in range(N_NODES)
        ]
        wpn = max(workers // N_NODES, 1)

        def claim(wid):
            return trees[(wid // wpn) % N_NODES].claim(wid)

        try:
            served, wall = _drain_threads(claim, workers, CALC_DELAY_S)
        finally:
            for t in trees:
                t.close()
            gsrc.close()
    return {
        "name": f"{transport}-w{workers}",
        "transport": transport,
        "workers": workers,
        "steps_served": served,
        "wall_s": round(wall, 4),
        "claims_per_s": round(served / wall, 1),
    }


# ---------------------------------------------------------------------------
# makespan: real worker processes through SimulatedCluster
# ---------------------------------------------------------------------------


def _makespan_cell(transport, workers):
    params = DLSParams(N=MAKESPAN_N, P=workers, min_chunk=MIN_CHUNK)
    fn = functools.partial(_work, ITER_S)
    with SimulatedCluster(
        "fsc", params,
        n_nodes=N_NODES, workers_per_node=workers // N_NODES,
        transport=transport,
        mode="cca" if transport == "cca" else "auto",
        link_latency_s=0.0,
    ) as cl:
        res = cl.run(fn, join_timeout=180, heartbeat_timeout_s=30.0)
    assert res.covers_exactly(MAKESPAN_N), (
        f"{transport}/{workers}: coverage broke ({res.executed}/{MAKESPAN_N})"
    )
    return {
        "name": f"{transport}-w{workers}",
        "transport": transport,
        "workers": workers,
        "makespan_s": round(res.wall_s, 4),
        "n_chunks": res.n_chunks,
        "covered": True,
        "serial_work_s": round(MAKESPAN_N * ITER_S, 4),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="OUT")
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (CI smoke): counts 4/8, skip 32/64")
    args = ap.parse_args()

    claim_counts = [4, 8] if args.quick else [4, 8, 16, 32, 64]
    makespan_counts = [8] if args.quick else [8, 16, 32, 64]

    claims = []
    for workers in claim_counts:
        for transport in ("dca", "cca", "tree"):
            cell = _claims_cell(transport, workers)
            claims.append(cell)
            print(f"claims  {transport:4s} W={workers:<3d} "
                  f"{cell['claims_per_s']:>9.1f}/s  wall={cell['wall_s']:.3f}s")

    makespans = []
    for workers in makespan_counts:
        for transport in ("dca", "cca", "tree"):
            cell = _makespan_cell(transport, workers)
            makespans.append(cell)
            print(f"makespan {transport:4s} W={workers:<3d} "
                  f"{cell['makespan_s']:.3f}s  chunks={cell['n_chunks']}")

    by_claims = {(c["transport"], c["workers"]): c for c in claims}
    dca_beats_cca = all(
        by_claims["dca", w]["claims_per_s"] > by_claims["cca", w]["claims_per_s"]
        for w in claim_counts
    )
    tree_64 = any(
        m["transport"] == "tree" and m["workers"] >= 64 and m["covered"]
        for m in makespans
    )
    headline = {
        "dca_beats_cca_all_counts": bool(dca_beats_cca),
        "tree_sustains_64_workers": bool(tree_64),
        "n_nodes": N_NODES,
        "worker_counts": claim_counts,
    }
    print(f"headline: {headline}")

    doc = {
        "meta": {
            "bench": "dist_scaling",
            "calc_delay_s": CALC_DELAY_S,
            "claim_steps": CLAIM_STEPS,
            "makespan_N": MAKESPAN_N,
            "iter_s": ITER_S,
            "min_chunk": MIN_CHUNK,
            "n_nodes": N_NODES,
            "quick": args.quick,
            "platform": platform.platform(),
            "python": platform.python_version(),
        },
        "claims": claims,
        "makespans": makespans,
        "headline": headline,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if not dca_beats_cca or not tree_64:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
