"""Chaos recovery cost: faulted vs fault-free makespan, per fault kind.

For every ``fault_suite`` scenario x both process backends (shared-static
DCA, foreman CCA) this runs the same workload twice through
``DistributedExecutor`` — once with the scenario's fault stripped (the
slowdown/delay family alone) and once with the fault armed — and reports:

* ``makespan_clean_s`` / ``makespan_faulted_s`` and their ratio
  ``inflation`` — what surviving the fault actually costs end to end;
* ``detect_latency_s`` — time from run start to the parent noticing the
  failure (for hangs this includes the heartbeat timeout by construction);
* ``recovery_s`` — the online lease-reclaim + re-execution cost;
* ``failures_detected`` / ``reclaimed_chunks`` / ``respawns`` /
  ``coordinator_restarts`` — the survival evidence, which the regression
  gate checks for presence (a silently-not-firing fault shrinks coverage).

The capstone row ``coordinator_kill_advantage`` compares DCA vs CCA
inflation under the coordinator kill: the paper's decentralization argument
as a measured number (DCA has no coordinator to lose, so its inflation
stays ~1.0 while CCA pays detection + restart + reconnect).

Wall times here are machine-scheduling time: the CI gate skips the ``_s``
leaves and compares the dimensionless inflation ratios and survival counts
(see .github/workflows/ci.yml, bench-gate job).

Run:  JAX_PLATFORMS=cpu PYTHONPATH=src python benchmarks/chaos_recovery.py \
          [--json out.json]

The committed snapshot is BENCH_chaos_recovery.json.
"""

import argparse
import functools
import json
import os
import platform
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core.techniques import DLSParams
from repro.dist import DistributedExecutor
from repro.select.scenarios import PerturbationScenario, fault_suite

N = 3000
WORKERS = 4
ITER_S = 1e-3  # ~3s serial work: faults land mid-run, runs stay CI-sized
HORIZON_S = 1.0
HEARTBEAT_S = 1.0
TECH = "fac"


def _work(per_iter_s, lo, hi):
    time.sleep((hi - lo) * per_iter_s)


def _strip_faults(scen):
    """The same slowdown/delay family with the fault family removed."""
    return PerturbationScenario(
        f"{scen.name}_clean", scen.profiles, scen.delay_calc_s
    )


def _run_once(scen, mode):
    fn = functools.partial(_work, ITER_S)
    with DistributedExecutor(
        TECH, DLSParams(N=N, P=WORKERS), mode=mode, scenario=scen
    ) as ex:
        t = ex.run(
            fn,
            WORKERS,
            join_timeout=120,
            heartbeat_timeout_s=HEARTBEAT_S,
            respawn=True,
        )
        rng = ex.executed_ranges()
        assert rng[0, 0] == 0 and rng[-1, 1] == N, "coverage broke under chaos"
        return t, ex


def bench_cell(scen, mode):
    t_clean, _ = _run_once(_strip_faults(scen), mode)
    t_fault, ex = _run_once(scen, mode)
    detect = [f["t_detect_s"] for f in ex.failures]
    recover = [f["recovery_s"] for f in ex.failures]
    return {
        "scenario": scen.name,
        "mode": mode,
        "fault_kinds": sorted({f.kind for f in scen.faults}),
        "makespan_clean_s": round(t_clean, 4),
        "makespan_faulted_s": round(t_fault, 4),
        "inflation": round(t_fault / t_clean, 3),
        "detect_latency_s": round(max(detect), 4) if detect else 0.0,
        "recovery_s": round(sum(recover), 4),
        "failures_detected": len(ex.failures),
        "reclaimed_chunks": len(ex.reclaimed),
        "respawns": ex.respawns,
        "coordinator_restarts": getattr(ex.source, "restarts", 0),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="OUT")
    args = ap.parse_args()

    cells = []
    for scen in fault_suite(WORKERS, horizon_s=HORIZON_S):
        for mode in ("dca", "cca"):
            cell = bench_cell(scen, mode)
            cells.append(cell)
            print(
                f"{cell['scenario']:17s} {mode}: "
                f"clean={cell['makespan_clean_s']:.2f}s "
                f"faulted={cell['makespan_faulted_s']:.2f}s "
                f"x{cell['inflation']:.2f}  "
                f"detect={cell['detect_latency_s']:.2f}s "
                f"failures={cell['failures_detected']} "
                f"respawns={cell['respawns']} "
                f"coord_restarts={cell['coordinator_restarts']}"
            )

    by = {(c["scenario"], c["mode"]): c for c in cells}
    advantage = {
        # CCA inflation minus DCA inflation under the coordinator kill;
        # positive == decentralization pays off under coordinator loss
        "cca_minus_dca_inflation": round(
            by["coordinator_down", "cca"]["inflation"]
            - by["coordinator_down", "dca"]["inflation"],
            3,
        ),
        "dca_inflation": by["coordinator_down", "dca"]["inflation"],
        "cca_inflation": by["coordinator_down", "cca"]["inflation"],
    }
    print(f"coordinator_kill_advantage: {advantage}")

    doc = {
        "meta": {
            "bench": "chaos_recovery",
            "N": N,
            "workers": WORKERS,
            "iter_s": ITER_S,
            "technique": TECH,
            "heartbeat_timeout_s": HEARTBEAT_S,
            "platform": platform.platform(),
            "python": platform.python_version(),
        },
        "cells": cells,
        "coordinator_kill_advantage": advantage,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
