"""Benchmark regression gate: fresh JSON vs committed BENCH_*.json snapshot.

Compares numeric leaves by flattened path (list entries are keyed by their
``name``/``scenario`` field where present, by index otherwise) and fails when
a fresh value exceeds the committed one by more than ``--tolerance`` x, or
when a committed entry disappeared (coverage shrank).  Timings below
``--min-value`` are skipped — sub-threshold numbers are scheduler noise, not
signal.  Metadata strings (platform, python) are ignored; ``derived``
strings are compared exactly under ``--derived-exact`` (they encode
deterministic outputs like chunk counts).

``--require-true KEY`` additionally asserts a headline boolean (for
example ``dca_beats_cca_all_counts`` in BENCH_dist_scaling.json) exists in
the fresh run and is true everywhere it appears — machine-independent
claims stay gated even when every timing leaf is skipped.

Exit status 0 == no regression.  Used by the CI bench-gate job.

Run:  python benchmarks/check_regression.py fresh.json BENCH_committed.json \
          [--tolerance 3.0] [--min-value 5.0] [--derived-exact] \
          [--skip KEY] [--require-true KEY]
"""

import argparse
import json
import sys


def flatten(doc, prefix=""):
    """Yield (path, leaf) pairs; list items keyed by name/scenario fields."""
    if isinstance(doc, dict):
        for k, v in doc.items():
            yield from flatten(v, f"{prefix}.{k}" if prefix else str(k))
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            key = v.get("name", v.get("scenario", i)) if isinstance(v, dict) else i
            yield from flatten(v, f"{prefix}[{key}]")
    else:
        yield prefix, doc


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh")
    ap.add_argument("committed")
    ap.add_argument("--tolerance", type=float, default=3.0,
                    help="fail when fresh > committed * tolerance")
    ap.add_argument("--min-value", type=float, default=5.0,
                    help="skip numeric comparisons below this (noise floor)")
    ap.add_argument("--derived-exact", action="store_true",
                    help="require 'derived' strings to match exactly")
    ap.add_argument("--skip", action="append", default=[], metavar="KEY",
                    help="leaf key names to exclude (e.g. machine wall times)")
    ap.add_argument("--require-true", action="append", default=[],
                    metavar="KEY", dest="require_true",
                    help="leaf key that must exist in the fresh run and be "
                    "boolean true everywhere it appears (headline claims "
                    "like dca_beats_cca_all_counts)")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = dict(flatten(json.load(f)))
    with open(args.committed) as f:
        committed = dict(flatten(json.load(f)))

    failures = []
    compared = 0
    for path, want in committed.items():
        leaf = path.rsplit(".", 1)[-1]
        if leaf in args.skip:
            continue
        have = fresh.get(path)
        if isinstance(want, bool) or not isinstance(want, (int, float)):
            if (
                args.derived_exact
                and path.endswith(".derived")
                and have != want
            ):
                failures.append(f"{path}: derived changed: {have!r} != {want!r}")
            continue
        if have is None:
            failures.append(f"{path}: missing from fresh run (coverage shrank)")
            continue
        if not isinstance(have, (int, float)) or isinstance(have, bool):
            failures.append(f"{path}: expected a number, got {have!r}")
            continue
        if max(abs(want), abs(have)) < args.min_value:
            continue  # both under the noise floor
        compared += 1
        if want > 0 and have > want * args.tolerance:
            failures.append(
                f"{path}: {have:.2f} vs committed {want:.2f} "
                f"(>{args.tolerance:.1f}x regression)"
            )

    for key in args.require_true:
        hits = [(p, v) for p, v in fresh.items()
                if p.rsplit(".", 1)[-1] == key]
        if not hits:
            failures.append(f"--require-true {key}: no such leaf in fresh run")
        for path, v in hits:
            if v is not True:
                failures.append(f"{path}: required true, got {v!r}")

    print(f"# compared {compared} numeric leaves "
          f"({len(committed)} committed, {len(fresh)} fresh)")
    for line in failures:
        print(f"REGRESSION {line}")
    if failures:
        print(f"# {len(failures)} regression(s) beyond {args.tolerance}x")
        return 1
    print("# no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
