"""Beyond-paper benchmarks: the DLS machinery inside the training framework.

  chunk_calc_scaling — chunk-calculation cost vs P: sequential CCA recursion
                       vs vectorized DCA closed forms vs the Pallas kernel
                       (interpret mode): the TPU adaptation's headline win.
  data_balance       — token-load imbalance of the DLS data scheduler vs
                       STATIC over a heavy-tailed corpus.
  straggler          — self-scheduled microbatches under a slow host.
  sspmd_roundtrip    — device-level DCA rounds: schedule agreement with host.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.executor import SelfSchedulingExecutor
from repro.core.schedule import build_schedule_cca, build_schedule_dca
from repro.core.techniques import DLSParams
from repro.data import DLSBatchScheduler, SyntheticCorpus
from repro.runtime import StragglerMitigator


def bench_chunk_calc_scaling(emit):
    n = 262_144
    for p in (16, 64, 256, 1024):
        params = DLSParams(N=n, P=p)
        t0 = time.perf_counter()
        cca = build_schedule_cca("gss", params)
        t_cca = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        dca = build_schedule_dca("gss", params)
        t_dca = (time.perf_counter() - t0) * 1e6
        emit(f"chunk_calc/gss/P{p}", t_dca,
             f"cca_us={t_cca:.0f};dca_us={t_dca:.0f};steps={dca.num_steps};"
             f"speedup={t_cca/max(t_dca,1e-9):.1f}x")


def bench_chunk_calc_kernel(emit):
    from repro.kernels.dls_chunks import dls_chunk_schedule

    params = DLSParams(N=262_144, P=256)
    t0 = time.perf_counter()
    sizes, offs = dls_chunk_schedule("fac", params, interpret=True)
    dt = (time.perf_counter() - t0) * 1e6
    kept = int((np.asarray(sizes) > 0).sum())
    emit("chunk_calc/pallas_fac", dt, f"steps={kept};interpret=True")


def bench_data_balance(emit):
    c = SyntheticCorpus(vocab=1000, n_docs=4000, sigma=1.0, seed=1)
    c.lengths = np.sort(c.lengths)[::-1].copy()  # adversarial order
    for tech in ("static", "gss", "fac", "fiss"):
        s = DLSBatchScheduler(c, n_groups=16, technique=tech)
        t0 = time.perf_counter()
        loads = s.group_token_loads(s.schedule.num_steps // 16)
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"data_balance/{tech}", dt,
             f"imbalance={loads.max()/loads.mean()-1:.4f}")


def bench_straggler(emit):
    import time as _t

    for tech in ("static", "fac"):
        m = StragglerMitigator(n_micro=48, n_groups=4, technique=tech)
        t0 = time.perf_counter()
        m.run(lambda i: _t.sleep(0.0005))
        dt = (time.perf_counter() - t0) * 1e6
        done = m.chunks_executed()
        emit(f"straggler/{tech}", dt, f"per_worker={sorted(done.values())}")


def bench_hierarchical(emit):
    """Two-level DCA: global-counter contention vs flat self-scheduling."""
    from repro.core.hierarchical import HierarchicalExecutor

    n = 100_000
    for groups, wpg in ((8, 8), (16, 16)):
        ex = HierarchicalExecutor(n, groups, wpg, "gss", "fac")
        t0 = time.perf_counter()
        ex.run(lambda lo, hi: None)
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"hierarchical/g{groups}x{wpg}", dt,
             f"global_claims={ex.global_contention_events};"
             f"flat_claims_equiv={n};chunks={len(ex.records)}")


def bench_executor_modes(emit):
    """CCA vs DCA thread executor under injected calc delay (the paper's
    experiment, real threads instead of simulation)."""
    n, w = 2_000, 8
    for mode in ("cca", "dca"):
        for delay in (0.0, 2e-4):
            ex = SelfSchedulingExecutor("fsc", DLSParams(N=n, P=w), mode=mode,
                                        calc_delay_s=delay)
            t = ex.run(lambda lo, hi: None, n_workers=w)
            emit(f"executor/{mode}/delay{int(delay*1e6)}us", t * 1e6,
                 f"wall_s={t:.4f}")
