"""StaticSource claim overhead vs the pre-refactor inlined executor loop,
plus the cross-process claim costs (shared-static DCA vs foreman CCA).

Thread section: the ChunkSource redesign replaced the executor's inlined DCA
claim path (lock-guarded step fetch-and-add + schedule table lookup) with
``StaticSource.claim`` (itertools.count fetch-and-add, no lock).  This bench
pins that the protocol indirection costs nothing: ns/claim for both paths,
single-threaded and contended, plus the ratio.

Process section: the paper's actual claim (Sec. 5) — a shared-memory
fetch-and-add + table read (``SharedStaticSource``, the DCA placement)
against a coordinator round-trip per chunk (``ForemanSource``, the CCA
placement), measured from inside real worker processes so startup is
excluded.  The DCA-vs-CCA gap here is the per-claim cost the slowdown
experiments amplify.

Injector section: the scenario-injection layer (runtime/inject.py) must not
tax the claim hot path — ns/claim through an ``InjectedSource`` wrapping a
StaticSource under a *non-constant* scenario (zero configured delay, so the
number is pure wrapper overhead) next to the bare source, plus the cost of
one shared-clock speed sample (``ScenarioInjector.slowdown``, paid once per
chunk, not per claim).

Run:  JAX_PLATFORMS=cpu PYTHONPATH=src python benchmarks/source_overhead.py [--json out.json]

The committed snapshot is BENCH_source_overhead.json (bench-gate job).
"""

import argparse
import json
import os
import sys
import threading
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core.schedule import build_schedule_dca
from repro.core.source import StaticSource
from repro.core.techniques import DLSParams
from repro.dist import SharedStaticSource
from repro.dist.sources import _process_source_for
from repro.dist.shm import default_context


class _InlinedLoop:
    """The pre-refactor SelfSchedulingExecutor._claim_dca, verbatim shape:
    lock-guarded fetch-and-add, then closed-form table lookup outside it."""

    def __init__(self, schedule):
        self._schedule = schedule
        self._lock = threading.Lock()
        self._step = 0

    def claim(self):
        with self._lock:  # the fetch-and-add critical section
            step = self._step
            if step >= self._schedule.num_steps:
                return None
            self._step += 1
        lo = int(self._schedule.offsets[step])
        hi = lo + int(self._schedule.sizes[step])
        return step, lo, hi


def _drain_timed(claim, n_threads: int) -> float:
    """Wall time to drain the whole schedule across n_threads claimers."""

    def worker():
        while claim() is not None:
            pass

    t0 = time.perf_counter()
    if n_threads == 1:
        worker()
    else:
        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    return time.perf_counter() - t0


def bench(n_claims: int = 200_000, n_threads: int = 4, repeats: int = 5) -> dict:
    # SS: one chunk per iteration -> num_steps == n_claims claim events
    params = DLSParams(N=n_claims, P=8)
    schedule = build_schedule_dca("ss", params)
    out = {"n_claims": n_claims, "technique": "ss", "threads_contended": n_threads}
    for label, threads in (("1thread", 1), (f"{n_threads}threads", n_threads)):
        olds, news = [], []
        for _ in range(repeats):
            inlined = _InlinedLoop(schedule)
            olds.append(_drain_timed(inlined.claim, threads))
            src = StaticSource(schedule)
            news.append(_drain_timed(lambda: src.claim(0), threads))
        old, new = min(olds), min(news)
        out[f"inlined_ns_per_claim_{label}"] = old / n_claims * 1e9
        out[f"source_ns_per_claim_{label}"] = new / n_claims * 1e9
        out[f"ratio_{label}"] = new / old
    return out


def bench_injector(n_claims: int = 200_000, repeats: int = 5) -> dict:
    """Claim latency with vs without a non-constant scenario attached."""
    from repro.runtime.inject import InjectedSource, ScenarioInjector
    from repro.select.scenarios import PerturbationScenario

    params = DLSParams(N=n_claims, P=8)
    schedule = build_schedule_dca("ss", params)
    scen = PerturbationScenario.bursty(
        8, pe=1, windows=[(0.1, 0.5)], factor=0.5
    )  # time-varying, zero delay: the wrapper cost alone
    bares, injs = [], []
    with ScenarioInjector(scen) as injector:
        injector.start()
        for _ in range(repeats):
            src = StaticSource(schedule)
            bares.append(_drain_timed(lambda: src.claim(0), 1))
            wrapped = InjectedSource(StaticSource(schedule), injector.delay_calc_s)
            injs.append(_drain_timed(lambda: wrapped.claim(0), 1))
        # the per-chunk speed sample (shared clock + padded-table lookup)
        n_samples = 50_000
        t0 = time.perf_counter()
        for _ in range(n_samples):
            injector.slowdown(1)
        sample_ns = (time.perf_counter() - t0) / n_samples * 1e9
    out = {
        "injector_bare_ns_per_claim": min(bares) / n_claims * 1e9,
        "injector_injected_ns_per_claim": min(injs) / n_claims * 1e9,
        "injector_overhead_ratio": min(injs) / min(bares),
        "injector_slowdown_sample_ns": sample_ns,
    }
    return out


def _timed_drain_worker(source, q):
    """Runs inside a worker process: drain, report (count, claim seconds)."""
    n = 0
    t0 = time.perf_counter()
    while source.claim(0) is not None:
        n += 1
    q.put((n, time.perf_counter() - t0))


def _process_ns_per_claim(source, n_procs: int, ctx) -> float:
    """Mean per-claim latency observed by the workers (startup excluded)."""
    q = ctx.Queue()
    procs = [
        ctx.Process(target=_timed_drain_worker, args=(source, q))
        for _ in range(n_procs)
    ]
    for p in procs:
        p.start()
    totals = [q.get(timeout=300) for _ in procs]
    for p in procs:
        p.join(timeout=60)
    claims = sum(n for n, _ in totals)
    elapsed = sum(t for _, t in totals)
    return elapsed / max(claims, 1) * 1e9


def bench_process(n_claims: int = 20_000, n_procs: int = 4, repeats: int = 3) -> dict:
    """Cross-process rows: shared-static DCA claim vs foreman CCA round-trip.

    SS again (one chunk per iteration == one claim event per iteration), so
    the numbers are per-claim costs of the two placements, nothing else.
    """
    params = DLSParams(N=n_claims, P=n_procs)
    ctx = default_context()
    out = {"process_n_claims": n_claims, "process_workers": n_procs}
    shared, foreman = [], []
    for _ in range(repeats):
        src = SharedStaticSource.build("ss", params, ctx=ctx)
        shared.append(_process_ns_per_claim(src, n_procs, ctx))
        src.close()
        src = _process_source_for("ss", params, "cca", ctx=ctx)
        foreman.append(_process_ns_per_claim(src, n_procs, ctx))
        src.close()
    out[f"shared_static_ns_per_claim_{n_procs}procs"] = min(shared)
    out[f"foreman_ns_per_claim_{n_procs}procs"] = min(foreman)
    # the DCA-vs-CCA claim-cost gap at the process level (expected >> 1).
    # NOT regression-gated (ci passes --skip for it): a *faster* shared-static
    # claim raises the ratio, which must never read as a regression
    out["foreman_over_shared_static"] = min(foreman) / min(shared)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    ap.add_argument("--claims", type=int, default=200_000)
    ap.add_argument("--process-claims", type=int, default=20_000)
    ap.add_argument("--skip-process", action="store_true",
                    help="thread rows only (e.g. on platforms without fork)")
    args = ap.parse_args()
    res = bench(n_claims=args.claims)
    res.update(bench_injector(n_claims=args.claims))
    if not args.skip_process:
        res.update(bench_process(n_claims=args.process_claims))
    print(json.dumps(res, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2)
