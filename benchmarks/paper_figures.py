"""Benchmarks reproducing the paper's tables/figures.

  table2   — chunk sequences, N=1000/P=4 (paper Table 2)
  fig1     — chunk-size patterns vs scheduling step (paper Fig. 1)
  fig4     — PSIA T_loop_par, CCA vs DCA x techniques x delays (paper Fig. 4)
  fig5     — Mandelbrot T_loop_par, same factorial (paper Fig. 5)

The factorial follows Table 4: techniques x {cca, dca} x delays {0, 10, 100}us.
``--full`` uses the paper's exact scale (N=262,144 / P=256); the default
shrinks 4x for CI speed while preserving the master-saturation regime.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.schedule import build_schedule_dca
from repro.core.simulator import SimConfig, mandelbrot_costs, psia_costs, simulate
from repro.core.techniques import DLSParams, TECHNIQUES

TECHS = ["static", "ss", "fsc", "gss", "tap", "tss", "fac", "tfss", "fiss",
         "viss", "rnd", "pls", "af"]
DELAYS = [0.0, 1e-5, 1e-4]


def bench_table2(emit):
    params = DLSParams(N=1000, P=4, h=0.013716, sigma=0.2, tap_va=3.025e-4)
    for tech in TECHS:
        if tech == "af":
            continue
        t0 = time.perf_counter()
        sched = build_schedule_dca(tech, params)
        dt = (time.perf_counter() - t0) * 1e6
        head = ",".join(str(int(s)) for s in sched.sizes[:6])
        emit(f"table2/{tech}", dt, f"chunks={sched.num_steps};head={head}")


def bench_fig1(emit):
    params = DLSParams(N=1000, P=4)
    for tech in ("fsc", "gss", "fiss", "rnd"):  # one per pattern class
        sched = build_schedule_dca(tech, params)
        pat = TECHNIQUES[tech].pattern
        emit(f"fig1/{tech}", 0.0,
             f"pattern={pat};K0={int(sched.sizes[0])};K_last={int(sched.sizes[-1])}")


def _factorial(emit, app: str, costs, n, p):
    for tech in TECHS:
        for approach in ("cca", "dca"):
            for delay in DELAYS:
                cfg = SimConfig(
                    technique=tech, params=DLSParams(N=n, P=p),
                    approach=approach, delay_calc_s=delay,
                )
                t0 = time.perf_counter()
                res = simulate(cfg, costs)
                dt = (time.perf_counter() - t0) * 1e6
                emit(
                    f"{app}/{tech}/{approach}/delay{int(delay*1e6)}us",
                    dt,
                    f"T_par={res.t_parallel:.4f};chunks={res.num_chunks};"
                    f"cov={res.cov_finish:.4f}",
                )


def bench_fig4(emit, full: bool = False):
    n, p = (262_144, 256) if full else (65_536, 256)
    costs = psia_costs(n, mean_s=0.07298 if full else 0.018)
    _factorial(emit, "fig4_psia", costs, n, p)


def bench_fig5(emit, full: bool = False):
    n, p = (262_144, 256) if full else (65_536, 256)
    costs = mandelbrot_costs(n, conversion_threshold=512 if full else 256,
                             mean_s=0.01025 if full else 0.0025)
    _factorial(emit, "fig5_mandelbrot", costs, n, p)
