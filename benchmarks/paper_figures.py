"""Benchmarks reproducing the paper's tables/figures.

  table2   — chunk sequences, N=1000/P=4 (paper Table 2)
  fig1     — chunk-size patterns vs scheduling step (paper Fig. 1)
  fig4     — PSIA T_loop_par, CCA vs DCA x techniques x delays (paper Fig. 4)
  fig5     — Mandelbrot T_loop_par, same factorial (paper Fig. 5)

The factorial follows Table 4: techniques x {cca, dca} x delays {0, 10, 100}us.
``--full`` uses the paper's exact scale (N=262,144 / P=256); the default
shrinks 4x for CI speed while preserving the master-saturation regime.
"""

from __future__ import annotations

import time

from repro.core.fastsim import simulate_sweep
from repro.core.schedule import build_schedule_dca
from repro.core.simulator import SimConfig, mandelbrot_costs, psia_costs, simulate
from repro.core.techniques import DLSParams, TECHNIQUES

TECHS = ["static", "ss", "fsc", "gss", "tap", "tss", "fac", "tfss", "fiss",
         "viss", "rnd", "pls", "af"]
DELAYS = [0.0, 1e-5, 1e-4]


def bench_table2(emit):
    params = DLSParams(N=1000, P=4, h=0.013716, sigma=0.2, tap_va=3.025e-4)
    for tech in TECHS:
        if tech == "af":
            continue
        t0 = time.perf_counter()
        sched = build_schedule_dca(tech, params)
        dt = (time.perf_counter() - t0) * 1e6
        head = ",".join(str(int(s)) for s in sched.sizes[:6])
        emit(f"table2/{tech}", dt, f"chunks={sched.num_steps};head={head}")


def bench_fig1(emit):
    params = DLSParams(N=1000, P=4)
    for tech in ("fsc", "gss", "fiss", "rnd"):  # one per pattern class
        sched = build_schedule_dca(tech, params)
        pat = TECHNIQUES[tech].pattern
        emit(f"fig1/{tech}", 0.0,
             f"pattern={pat};K0={int(sched.sizes[0])};K_last={int(sched.sizes[-1])}")


def _factorial(emit, app: str, costs, n, p):
    """The Table-4 factorial through ``simulate_sweep`` — one batched call
    per workload (AF rides the event engine inside the sweep)."""
    params = DLSParams(N=n, P=p)
    t0 = time.perf_counter()
    rows = simulate_sweep(params, costs, TECHS, delays_s=DELAYS)
    dt_per_row = (time.perf_counter() - t0) * 1e6 / len(rows)
    for row in rows:
        emit(
            f"{app}/{row['technique']}/{row['approach']}/"
            f"delay{int(row['delay_us'])}us",
            dt_per_row,
            f"T_par={row['t_parallel']:.4f};chunks={row['num_chunks']};"
            f"cov={row['cov_finish']:.4f};engine={row['engine']}",
        )


def _workload(app: str, full: bool):
    n, p = (262_144, 256) if full else (65_536, 256)
    if app == "fig4_psia":
        return psia_costs(n, mean_s=0.07298 if full else 0.018), n, p
    return mandelbrot_costs(n, conversion_threshold=512 if full else 256,
                            mean_s=0.01025 if full else 0.0025), n, p


def bench_fig4(emit, full: bool = False):
    costs, n, p = _workload("fig4_psia", full)
    _factorial(emit, "fig4_psia", costs, n, p)


def bench_fig5(emit, full: bool = False):
    costs, n, p = _workload("fig5_mandelbrot", full)
    _factorial(emit, "fig5_mandelbrot", costs, n, p)


def bench_engine_speedup(emit, full: bool = False):
    """Old (per-chunk heapq) vs new (round-based vectorized) engine on the
    fig4/fig5 sweeps — the perf claim of the analytic schedule engine.

    AF is excluded: it runs on the event engine in both cases (Sec. 4).
    """
    techs = [t for t in TECHS if t != "af"]
    for app in ("fig4_psia", "fig5_mandelbrot"):
        costs, n, p = _workload(app, full)
        params = DLSParams(N=n, P=p)

        t0 = time.perf_counter()
        rows = simulate_sweep(params, costs, techs, delays_s=DELAYS)
        t_new = time.perf_counter() - t0

        t0 = time.perf_counter()
        for tech in techs:
            for approach in ("cca", "dca"):
                for delay in DELAYS:
                    simulate(SimConfig(technique=tech, params=params,
                                       approach=approach, delay_calc_s=delay),
                             costs)
        t_old = time.perf_counter() - t0

        emit(f"engine/{app}/event", t_old * 1e6,
             f"rows={len(rows)};N={n};P={p}")
        emit(f"engine/{app}/analytic", t_new * 1e6,
             f"rows={len(rows)};N={n};P={p}")
        emit(f"engine/{app}/speedup", 0.0, f"x={t_old / t_new:.2f}")

    # the adaptive family: AWF-B/C/D/E under the epoch source, event engine
    # vs the epoch-segmented vectorized engine (core/adaptsim) — bit-identical
    # outputs (tests/test_fastsim_equivalence.py), so this measures pure
    # engine cost.  AF stays event-driven in both columns and is excluded.
    from repro.core.adaptsim import simulate_adaptive

    awf = ["awf_b", "awf_c", "awf_d", "awf_e"]
    costs, n, p = _workload("fig5_mandelbrot", full)
    params = DLSParams(N=n, P=p)
    cfgs = [SimConfig(technique=t, params=params, approach="adaptive",
                      delay_calc_s=d) for t in awf for d in DELAYS]
    t0 = time.perf_counter()
    for cfg in cfgs:
        simulate(cfg, costs)
    t_old = time.perf_counter() - t0
    t0 = time.perf_counter()
    for cfg in cfgs:
        simulate_adaptive(cfg, costs)
    t_new = time.perf_counter() - t0
    emit("engine/adaptive_awf/event", t_old * 1e6,
         f"rows={len(cfgs)};N={n};P={p}")
    emit("engine/adaptive_awf/analytic", t_new * 1e6,
         f"rows={len(cfgs)};N={n};P={p}")
    emit("engine/adaptive_awf/speedup", 0.0, f"x={t_old / t_new:.2f}")
