"""Serve a small model with batched requests: continuous batching with
DLS-scheduled admission (the paper's technique at the serving layer).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.specs import model_param_defs
from repro.models import init_params
from repro.serve import Request, ServingEngine

cfg = get_smoke_config("yi-34b")
cfg = dataclasses.replace(cfg, param_dtype="float32", compute_dtype="float32")
params = init_params(model_param_defs(cfg), jax.random.key(0), cfg.param_dtype)

rng = np.random.default_rng(0)
N_REQ, SLOTS = 12, 4
requests = [
    Request(
        rid=i,
        prompt=rng.integers(0, cfg.vocab, int(rng.integers(4, 16))).astype(np.int32),
        max_new=int(rng.integers(4, 12)),
    )
    for i in range(N_REQ)
]
total_tokens = sum(len(r.prompt) + r.max_new for r in requests)

engine = ServingEngine(cfg, params, max_slots=SLOTS, max_len=64)
t0 = time.time()
done = engine.run(requests, technique="gss")  # GSS admission chunks
dt = time.time() - t0

print(f"{N_REQ} requests over {SLOTS} slots: {engine.ticks} engine ticks, "
      f"{total_tokens} tokens in {dt:.2f}s ({total_tokens/dt:.0f} tok/s)")
print(f"mean slot occupancy: {np.mean(engine.occupancy):.2f}/{SLOTS}")
for rid in sorted(done)[:3]:
    print(f"  request {rid}: generated {done[rid]}")
