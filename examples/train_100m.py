"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on CPU, with the DLS data scheduler, checkpointing, and a mid-run
injected failure + restart.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 200]
(~100M params; a few minutes on CPU.)
"""

import argparse

from repro.launch.train import train
from repro.models.config import ModelConfig


def config_100m() -> ModelConfig:
    # ~102M params: 12L, d=768, llama-style
    return ModelConfig(
        name="repro-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_ff=2048,
        vocab=32_000,
        period_pattern=("attn",),
        ffn_pattern=("dense",),
        param_dtype="float32",
        compute_dtype="float32",
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--fail-at", default="120", help="injected failure steps")
    args = ap.parse_args()

    cfg = config_100m()
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    fail_at = tuple(int(s) for s in args.fail_at.split(",") if s)
    train(
        cfg,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        ckpt_dir="/tmp/repro_100m_ckpt",
        ckpt_every=50,
        technique="fac",
        fail_at=fail_at,
        peak_lr=3e-4,
        log_every=20,
    )
