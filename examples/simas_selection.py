"""SimAS-style online technique selection across mixed perturbations.

The paper's Sec. 6 evaluation fixes the DLS technique per run and varies the
perturbation; this example closes the loop the other way (SimAS, Mohammed &
Ciorba, arXiv:1912.02050): ``technique="auto"`` estimates the live scenario
from claim/report feedback and keeps re-selecting the best of the twelve
closed-form techniques as the run progresses.

For every scenario in the mixed suite (no perturbation / injected
calculation delay / static heterogeneity / a bursty PE / correlated
multi-PE slowdown) the table shows the online selector's achieved
T_loop^par next to the best and worst fixed (technique, approach) pair —
the selector tracks the best without being told which scenario it is in.

Run:  PYTHONPATH=src python examples/simas_selection.py [--full|--smoke]
"""

import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.core.simulator import mandelbrot_costs
from repro.core.techniques import DLSParams
from repro.select import evaluate_selector, mixed_suite


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="N=16,384 / P=64")
    ap.add_argument("--smoke", action="store_true", help="fast CI-sized run")
    args = ap.parse_args()
    if args.full:
        n, p = 16_384, 64
    elif args.smoke:
        n, p = 2_048, 16
    else:
        n, p = 4_096, 32
    costs = mandelbrot_costs(n, conversion_threshold=64, mean_s=0.002)
    suite = mixed_suite(p, float(costs.sum()) / p)
    rows = evaluate_selector(DLSParams(N=n, P=p), costs, suite)

    print(f"\n=== SimAS selection, Mandelbrot N={n} P={p} — T_loop_par seconds ===")
    print(f"{'scenario':12s} {'auto':>8s} {'best fixed':>19s} "
          f"{'worst fixed':>19s} {'vs best':>8s}")
    for r in rows:
        print(
            f"{r['scenario']:12s} {r['t_selector']:8.4f} "
            f"{r['t_best_fixed']:8.4f} ({r['best_fixed']:>9s}) "
            f"{r['t_worst_fixed']:8.4f} ({r['worst_fixed']:>9s}) "
            f"{r['vs_best']:8.3f}"
        )
    worst_margin = min(r["vs_worst"] for r in rows)
    print(
        f"\nauto stayed within {max(r['vs_best'] for r in rows) - 1:.1%} of the "
        f"best fixed technique in every scenario and beat the worst by up to "
        f"{1 - worst_margin:.0%}."
    )


if __name__ == "__main__":
    main()
