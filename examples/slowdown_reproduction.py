"""Reproduce the paper's headline experiment (Sec. 6): CCA vs DCA under
injected chunk-calculation delays, on both applications.

Run:  PYTHONPATH=src python examples/slowdown_reproduction.py [--full|--smoke]

--full uses the paper's exact scale (262,144 iterations, 256 ranks); default
is 4x reduced; --smoke is a fast CI-sized run.  Expect: ~equal at 0/10us;
CCA collapses at 100us, worst for fine-chunk techniques (SS/FSC/AF) — the
paper's Fig. 4c/5c.  Feedback techniques (AWF-B, AF) additionally show the
"adaptive" column: the same technique under DCA semantics through
``AdaptiveSource`` (epoch-published weights), which keeps the calculation off
the critical path even though the chunks react to measured speeds.
"""

import argparse

from repro.core.simulator import SimConfig, mandelbrot_costs, psia_costs, simulate
from repro.core.techniques import DLSParams, get_technique

TECHS = ["static", "ss", "fsc", "gss", "tss", "fac", "fiss", "viss", "pls",
         "awf_b", "af"]
DELAYS = (0.0, 1e-5, 1e-4)


def run(app: str, costs, n, p):
    print(f"\n=== {app} (N={n}, P={p}) — T_loop_par seconds ===")
    header = f"{'technique':9s} " + "".join(
        f"{a}/{d}us".rjust(13)
        for a in ("cca", "dca", "adapt")
        for d in (0, 10, 100)
    )
    print(header)
    for tech in TECHS:
        adaptive = get_technique(tech).requires_feedback
        row = f"{tech:9s} "
        for approach in ("cca", "dca", "adaptive"):
            for delay in DELAYS:
                if approach == "adaptive" and not adaptive:
                    row += f"{'-':>13s}"
                    continue
                res = simulate(
                    SimConfig(technique=tech, params=DLSParams(N=n, P=p),
                              approach=approach, delay_calc_s=delay),
                    costs,
                )
                row += f"{res.t_parallel:13.3f}"
        print(row)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI-sized run (N=8,192, P=64)")
    args = ap.parse_args()
    if args.full:
        n, p = 262_144, 256
        ps, mb = psia_costs(n), mandelbrot_costs(n, conversion_threshold=512)
    elif args.smoke:
        n, p = 8_192, 64
        ps = psia_costs(n, mean_s=0.018)
        mb = mandelbrot_costs(n, conversion_threshold=64, mean_s=0.0025)
    else:
        n, p = 65_536, 256
        ps = psia_costs(n, mean_s=0.018)
        mb = mandelbrot_costs(n, conversion_threshold=256, mean_s=0.0025)
    run("PSIA", ps, n, p)
    run("Mandelbrot", mb, n, p)
