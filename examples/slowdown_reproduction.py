"""Reproduce the paper's headline experiment (Sec. 6): CCA vs DCA under
injected chunk-calculation delays, on both applications.

Run:  PYTHONPATH=src python examples/slowdown_reproduction.py [--full|--smoke]
      PYTHONPATH=src python examples/slowdown_reproduction.py --processes [--smoke]

--full uses the paper's exact scale (262,144 iterations, 256 ranks); default
is 4x reduced; --smoke is a fast CI-sized run.  Expect: ~equal at 0/10us;
CCA collapses at 100us, worst for fine-chunk techniques (SS/FSC/AF) — the
paper's Fig. 4c/5c.  Feedback techniques (AWF-B, AF) additionally show the
"adaptive" column: the same technique under DCA semantics through
``AdaptiveSource`` (epoch-published weights), which keeps the calculation off
the critical path even though the chunks react to measured speeds.

--processes swaps the simulator for the real thing: ``DistributedExecutor``
runs genuinely slowed-down *worker processes* (sleep-per-iteration workload,
calc delay injected per claim), claiming either from shared memory (DCA,
``SharedStaticSource``) or from a coordinator process (CCA,
``ForemanSource``).  Wall-clock times then show the same story as the
simulated figures, but measured on real OS processes.
"""

import argparse
import functools
import time

from repro.core.simulator import SimConfig, mandelbrot_costs, psia_costs, simulate
from repro.core.techniques import DLSParams, get_technique

TECHS = ["static", "ss", "fsc", "gss", "tss", "fac", "fiss", "viss", "pls",
         "awf_b", "af"]
DELAYS = (0.0, 1e-5, 1e-4)


def run(app: str, costs, n, p):
    print(f"\n=== {app} (N={n}, P={p}) — T_loop_par seconds ===")
    header = f"{'technique':9s} " + "".join(
        f"{a}/{d}us".rjust(13)
        for a in ("cca", "dca", "adapt")
        for d in (0, 10, 100)
    )
    print(header)
    for tech in TECHS:
        adaptive = get_technique(tech).requires_feedback
        row = f"{tech:9s} "
        for approach in ("cca", "dca", "adaptive"):
            for delay in DELAYS:
                if approach == "adaptive" and not adaptive:
                    row += f"{'-':>13s}"
                    continue
                res = simulate(
                    SimConfig(technique=tech, params=DLSParams(N=n, P=p),
                              approach=approach, delay_calc_s=delay),
                    costs,
                )
                row += f"{res.t_parallel:13.3f}"
        print(row)


def _sleep_work(iter_cost_s, lo, hi):
    """The slowed-down worker's loop body: constant cost per iteration."""
    time.sleep(iter_cost_s * (hi - lo))


def run_processes(n: int, workers: int, iter_cost_s: float, delays):
    """Real worker processes: shared-static DCA vs foreman CCA wall times."""
    from repro.dist import DistributedExecutor

    techs = ["ss", "gss", "fac", "awf_b"]
    print(f"\n=== cross-process (N={n}, {workers} worker processes, "
          f"{iter_cost_s * 1e6:.0f}us/iter) — wall seconds ===")
    header = f"{'technique':9s} " + "".join(
        f"{m}/{int(d * 1e6)}us".rjust(13) for m in ("cca", "dca") for d in delays
    )
    print(header)
    fn = functools.partial(_sleep_work, iter_cost_s)
    for tech in techs:
        row = f"{tech:9s} "
        for mode in ("cca", "dca"):
            # feedback techniques run their DCA column through the adaptive
            # epoch source (same promotion the thread executor makes; ask for
            # it explicitly rather than triggering the downgrade warning)
            eff = ("adaptive" if mode == "dca"
                   and get_technique(tech).requires_feedback else mode)
            for delay in delays:
                ex = DistributedExecutor(
                    tech, DLSParams(N=n, P=workers), mode=eff, calc_delay_s=delay
                )
                t = ex.run(fn, workers, join_timeout=600)
                ex.close()
                assert ex.executed_ranges()[-1, 1] == n  # coverage, always
                row += f"{t:13.3f}"
        print(row)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI-sized run (N=8,192, P=64)")
    ap.add_argument("--processes", action="store_true",
                    help="run the slowdown scenarios on real worker processes "
                         "(DistributedExecutor) instead of the simulator")
    args = ap.parse_args()
    if args.processes:
        if args.smoke:
            run_processes(n=2_000, workers=4, iter_cost_s=2e-5, delays=(0.0, 1e-4))
        elif args.full:
            run_processes(n=65_536, workers=16, iter_cost_s=5e-5, delays=(0.0, 1e-5, 1e-4))
        else:
            run_processes(n=8_192, workers=8, iter_cost_s=5e-5, delays=(0.0, 1e-4))
        raise SystemExit(0)
    if args.full:
        n, p = 262_144, 256
        ps, mb = psia_costs(n), mandelbrot_costs(n, conversion_threshold=512)
    elif args.smoke:
        n, p = 8_192, 64
        ps = psia_costs(n, mean_s=0.018)
        mb = mandelbrot_costs(n, conversion_threshold=64, mean_s=0.0025)
    else:
        n, p = 65_536, 256
        ps = psia_costs(n, mean_s=0.018)
        mb = mandelbrot_costs(n, conversion_threshold=256, mean_s=0.0025)
    run("PSIA", ps, n, p)
    run("Mandelbrot", mb, n, p)
