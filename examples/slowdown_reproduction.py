"""Reproduce the paper's headline experiment (Sec. 6): CCA vs DCA under
injected chunk-calculation delays, on both applications.

Run:  PYTHONPATH=src python examples/slowdown_reproduction.py [--full|--smoke]
      PYTHONPATH=src python examples/slowdown_reproduction.py --processes [--smoke]
      PYTHONPATH=src python examples/slowdown_reproduction.py --processes \
          --scenario bursty [--smoke]
      PYTHONPATH=src python examples/slowdown_reproduction.py --hosts 4 [--smoke]

--full uses the paper's exact scale (262,144 iterations, 256 ranks); default
is 4x reduced; --smoke is a fast CI-sized run.  Expect: ~equal at 0/10us;
CCA collapses at 100us, worst for fine-chunk techniques (SS/FSC/AF) — the
paper's Fig. 4c/5c.  Feedback techniques (AWF-B, AF) additionally show the
"adaptive" column: the same technique under DCA semantics through
``AdaptiveSource`` (epoch-published weights), which keeps the calculation off
the critical path even though the chunks react to measured speeds.

--processes swaps the simulator for the real thing: ``DistributedExecutor``
runs genuinely slowed-down *worker processes* (sleep-per-iteration workload,
calc delay injected per claim), claiming either from shared memory (DCA,
``SharedStaticSource``) or from a coordinator process (CCA,
``ForemanSource``).  Wall-clock times then show the same story as the
simulated figures, but measured on real OS processes.

--scenario picks a ``PerturbationScenario`` family beyond the paper's
constant delay (select/scenarios.py): per-PE speed profiles drive the run —
through ``SimConfig.scenario`` on the simulator path and through the
``ScenarioInjector`` (runtime/inject.py) on real threads/processes, where
profile tables live in shared memory and each chunk's execution is stretched
by the speed sampled at chunk start on a shared run clock.

The chaos scenarios (``crashy``, ``hangy``, ``stally``,
``coordinator_down`` — select/scenarios.py ``fault_suite``) additionally
SIGKILL/hang/stall real worker processes, or kill the CCA coordinator,
mid-run; they require ``--processes``.  The executor detects the failure
(heartbeats + exit codes), reclaims the lost lease, respawns the worker —
or, for ``coordinator_down``, the foreman supervisor restarts the
coordinator while DCA shrugs (nothing to kill).  Try:

    PYTHONPATH=src python examples/slowdown_reproduction.py \
        --processes --scenario crashy --smoke

--hosts N simulates a multi-host run on loopback (``repro.net``): N nodes
of worker processes, per-link TCP latency, three transports side by side —
remote-counter DCA (one fetch-and-add RPC per claim), network-foreman CCA
(calculate-then-reply round-trip), and the node-master tree (per-node
masters claim coarse global batches over TCP and re-serve them through
shared memory, keeping workers off the network on the common path).  On a
real cluster the same sources take ``host=`` for a non-loopback bind.
"""

import argparse
import functools
import time

from repro.core.simulator import SimConfig, mandelbrot_costs, psia_costs, simulate
from repro.core.techniques import DLSParams, get_technique

TECHS = ["static", "ss", "fsc", "gss", "tss", "fac", "fiss", "viss", "pls",
         "awf_b", "af"]
DELAYS = (0.0, 1e-5, 1e-4)
SCENARIOS = ("constant", "hetero", "bursty", "correlated")
FAULT_SCENARIOS = ("crashy", "hangy", "stally", "coordinator_down")


def scenario_for(name: str, P: int, horizon_s: float, delay_s: float):
    """One PerturbationScenario per family, window edges scaled to sit
    inside a run of roughly ``horizon_s`` seconds."""
    from repro.select.scenarios import PerturbationScenario, fault_suite

    h = float(horizon_s)
    quarter = max(P // 4, 1)
    if name in FAULT_SCENARIOS:
        scen = {s.name: s for s in fault_suite(P, h)}[name]
        if delay_s and delay_s != scen.delay_calc_s:
            scen = PerturbationScenario(
                scen.name, scen.profiles, delay_s, faults=scen.faults
            )
        return scen
    if name == "constant":
        return PerturbationScenario.constant(P, delay_calc_s=delay_s)
    if name == "hetero":
        return PerturbationScenario.variable(
            P, slow_pes=range(P - quarter, P), factor=0.25, delay_calc_s=delay_s
        )
    if name == "bursty":
        return PerturbationScenario.bursty(
            P, pe=1, windows=[(0.25 * h, 0.75 * h)], factor=0.25,
            delay_calc_s=delay_s,
        )
    if name == "correlated":
        return PerturbationScenario.correlated(
            P, pes=range(quarter), windows=[(0.1 * h, 0.6 * h)], factor=0.3,
            delay_calc_s=delay_s,
        )
    raise ValueError(f"unknown scenario {name!r} (choose from {SCENARIOS})")


def run(app: str, costs, n, p, scenario_name=None):
    title = f" — scenario={scenario_name}" if scenario_name else ""
    print(f"\n=== {app} (N={n}, P={p}){title} — T_loop_par seconds ===")
    header = f"{'technique':9s} " + "".join(
        f"{a}/{d}us".rjust(13)
        for a in ("cca", "dca", "adapt")
        for d in (0, 10, 100)
    )
    print(header)
    # rough horizon for window placement: serial work spread over P PEs
    horizon = float(costs[:n].sum()) / p * 2.0
    for tech in TECHS:
        adaptive = get_technique(tech).requires_feedback
        row = f"{tech:9s} "
        for approach in ("cca", "dca", "adaptive"):
            for delay in DELAYS:
                if approach == "adaptive" and not adaptive:
                    row += f"{'-':>13s}"
                    continue
                if scenario_name:
                    cfg = SimConfig(
                        technique=tech, params=DLSParams(N=n, P=p),
                        approach=approach,
                        scenario=scenario_for(scenario_name, p, horizon, delay),
                    )
                else:
                    cfg = SimConfig(
                        technique=tech, params=DLSParams(N=n, P=p),
                        approach=approach, delay_calc_s=delay,
                    )
                res = simulate(cfg, costs)
                row += f"{res.t_parallel:13.3f}"
        print(row)


def _sleep_work(iter_cost_s, lo, hi):
    """The slowed-down worker's loop body: constant cost per iteration."""
    time.sleep(iter_cost_s * (hi - lo))


def run_processes(n: int, workers: int, iter_cost_s: float, delays,
                  scenario_name=None):
    """Real worker processes: shared-static DCA vs foreman CCA wall times."""
    from repro.dist import DistributedExecutor

    techs = ["ss", "gss", "fac", "awf_b"]
    title = f", scenario={scenario_name}" if scenario_name else ""
    print(f"\n=== cross-process (N={n}, {workers} worker processes, "
          f"{iter_cost_s * 1e6:.0f}us/iter{title}) — wall seconds ===")
    header = f"{'technique':9s} " + "".join(
        f"{m}/{int(d * 1e6)}us".rjust(13) for m in ("cca", "dca") for d in delays
    )
    print(header)
    fn = functools.partial(_sleep_work, iter_cost_s)
    horizon = n * iter_cost_s / workers * 2.0
    notes = []  # chaos survival summaries, printed per technique row
    for tech in techs:
        row = f"{tech:9s} "
        for mode in ("cca", "dca"):
            # feedback techniques run their DCA column through the adaptive
            # epoch source (same promotion the thread executor makes; ask for
            # it explicitly rather than triggering the downgrade warning)
            eff = ("adaptive" if mode == "dca"
                   and get_technique(tech).requires_feedback else mode)
            for delay in delays:
                kw = (
                    dict(scenario=scenario_for(scenario_name, workers,
                                               horizon, delay))
                    if scenario_name else dict(calc_delay_s=delay)
                )
                chaotic = getattr(kw.get("scenario"), "has_faults", False)
                run_kw = (
                    dict(heartbeat_timeout_s=max(4 * horizon, 2.0),
                         respawn=True)
                    if chaotic else {}
                )
                ex = DistributedExecutor(
                    tech, DLSParams(N=n, P=workers), mode=eff, **kw
                )
                t = ex.run(fn, workers, join_timeout=600, **run_kw)
                ex.close()
                assert ex.executed_ranges()[-1, 1] == n  # coverage, always
                row += f"{t:13.3f}"
                if chaotic:
                    kinds = ",".join(f["kind"] for f in ex.failures) or "none"
                    restarts = getattr(ex.source, "restarts", 0)
                    notes.append(f"  {tech}/{mode}/{int(delay * 1e6)}us: "
                                 f"faults={kinds} respawns={ex.respawns} "
                                 f"coordinator_restarts={restarts}")
        print(row)
        for note in notes:
            print(note)
        notes.clear()


def run_cluster(n: int, hosts: int, workers_per_node: int, iter_cost_s: float,
                link_latency_s: float = 1e-3):
    """Multi-host simulation on loopback: N nodes x W workers per node,
    per-link TCP latency, all three repro.net transports side by side."""
    from repro.net import SimulatedCluster

    workers = hosts * workers_per_node
    print(f"\n=== simulated cluster (N={n}, {hosts} nodes x "
          f"{workers_per_node} workers, link={link_latency_s * 1e3:.1f}ms, "
          f"{iter_cost_s * 1e6:.0f}us/iter) — wall seconds ===")
    print(f"{'technique':9s} " + "".join(
        t.rjust(13) for t in ("dca", "cca", "tree")))
    fn = functools.partial(_sleep_work, iter_cost_s)
    for tech in ("ss", "fsc", "fac"):
        row = f"{tech:9s} "
        for transport in ("dca", "cca", "tree"):
            params = DLSParams(N=n, P=workers, min_chunk=4)
            with SimulatedCluster(
                tech, params, n_nodes=hosts,
                workers_per_node=workers_per_node, transport=transport,
                mode="cca" if transport == "cca" else "auto",
                link_latency_s=link_latency_s,
            ) as cl:
                res = cl.run(fn, join_timeout=600)
            assert res.covers_exactly(n)  # coverage, always
            row += f"{res.wall_s:13.3f}"
        print(row)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI-sized run (N=8,192, P=64)")
    ap.add_argument("--processes", action="store_true",
                    help="run the slowdown scenarios on real worker processes "
                         "(DistributedExecutor) instead of the simulator")
    ap.add_argument("--scenario", default=None,
                    choices=SCENARIOS + FAULT_SCENARIOS,
                    help="perturbation family beyond the paper's constant "
                         "delay (speed profiles injected into real execution "
                         "under --processes); the chaos families "
                         f"{FAULT_SCENARIOS} kill/hang/stall real processes "
                         "and require --processes")
    ap.add_argument("--hosts", type=int, default=None, metavar="N",
                    help="simulate a multi-host run on loopback (repro.net): "
                         "N nodes of worker processes with per-link TCP "
                         "latency, comparing the remote-counter DCA, "
                         "network-foreman CCA and node-master tree transports")
    args = ap.parse_args()
    if args.scenario in FAULT_SCENARIOS and not args.processes:
        ap.error(f"--scenario {args.scenario} injects real process faults; "
                 "it requires --processes")
    if args.hosts is not None:
        if args.hosts < 1:
            ap.error("--hosts must be >= 1")
        if args.smoke:
            run_cluster(n=2_000, hosts=args.hosts, workers_per_node=2,
                        iter_cost_s=2e-5)
        elif args.full:
            run_cluster(n=65_536, hosts=args.hosts, workers_per_node=8,
                        iter_cost_s=5e-5)
        else:
            run_cluster(n=8_192, hosts=args.hosts, workers_per_node=4,
                        iter_cost_s=5e-5)
        raise SystemExit(0)
    if args.processes:
        if args.smoke:
            run_processes(n=2_000, workers=4, iter_cost_s=2e-5,
                          delays=(0.0, 1e-4), scenario_name=args.scenario)
        elif args.full:
            run_processes(n=65_536, workers=16, iter_cost_s=5e-5,
                          delays=(0.0, 1e-5, 1e-4), scenario_name=args.scenario)
        else:
            run_processes(n=8_192, workers=8, iter_cost_s=5e-5,
                          delays=(0.0, 1e-4), scenario_name=args.scenario)
        raise SystemExit(0)
    if args.full:
        n, p = 262_144, 256
        ps, mb = psia_costs(n), mandelbrot_costs(n, conversion_threshold=512)
    elif args.smoke:
        n, p = 8_192, 64
        ps = psia_costs(n, mean_s=0.018)
        mb = mandelbrot_costs(n, conversion_threshold=64, mean_s=0.0025)
    else:
        n, p = 65_536, 256
        ps = psia_costs(n, mean_s=0.018)
        mb = mandelbrot_costs(n, conversion_threshold=256, mean_s=0.0025)
    run("PSIA", ps, n, p, scenario_name=args.scenario)
    run("Mandelbrot", mb, n, p, scenario_name=args.scenario)
