"""Quickstart: the paper's DLS techniques through the public API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    DLSParams,
    build_schedule_cca,
    build_schedule_dca,
    chunk_of_step,
    simulate,
    SimConfig,
    mandelbrot_costs,
    verify_coverage,
)
from repro.core.api import (
    Configure_Chunk_Calculation_Mode,
    DLS_EndChunk,
    DLS_EndLoop,
    DLS_Parameters_Setup,
    DLS_StartChunk,
    DLS_StartLoop,
    DLS_Terminated,
)

# 1. A chunk schedule: every DLS technique, both calculation approaches -------
params = DLSParams(N=10_000, P=8)
for tech in ("gss", "fac", "fiss", "tss"):
    dca = build_schedule_dca(tech, params)  # closed forms, vectorized
    cca = build_schedule_cca(tech, params)  # the master's recursion
    verify_coverage(dca)
    verify_coverage(cca)
    print(f"{tech:5s} chunks={dca.num_steps:4d}  first={dca.sizes[:5].tolist()}")

# 2. DCA's defining property: any PE computes its chunk from the step index --
lo, size = chunk_of_step("gss", 7, params)  # no global state consulted
print(f"\nstep 7 of GSS covers [{lo}, {lo + size}) — computed locally")

# 3. The paper's experiment: inject a delay into the chunk calculation -------
costs = mandelbrot_costs(16_384, conversion_threshold=128, mean_s=0.002)
for approach in ("cca", "dca"):
    res = simulate(
        SimConfig(technique="fac", params=DLSParams(N=16_384, P=64),
                  approach=approach, delay_calc_s=1e-4),
        costs,
    )
    print(f"{approach}: T_loop_par = {res.t_parallel:.3f}s  ({res.num_chunks} chunks)")

# 4. The LB4MPI-style API (paper Listing 1) ----------------------------------
info = DLS_Parameters_Setup(n_workers=4, N=1000, technique="fac")
Configure_Chunk_Calculation_Mode(info, "dca")
DLS_StartLoop(info)
total = 0
while not DLS_Terminated(info):
    chunk = DLS_StartChunk(info)
    if chunk is None:
        break
    lo, hi = chunk
    total += hi - lo  # ... compute iterations [lo, hi) ...
    DLS_EndChunk(info)
DLS_EndLoop(info)
print(f"\nLB4MPI-style loop covered {total} iterations")

# 5. The ChunkSource protocol: one API for every backend ----------------------
from repro.core import ScheduleSpec, make_source

for spec in (
    ScheduleSpec("fac", N=10_000, P=8, mode="dca"),  # lock-free static claims
    ScheduleSpec("fac", N=10_000, P=8, mode="cca"),  # recursion under the lock
    ScheduleSpec("awf_b", N=10_000, P=8, mode="adaptive"),  # AWF under DCA
    ScheduleSpec("gss", N=10_000, P=8, levels=(("gss", 4), ("fac", 2))),
):
    source = make_source(spec)
    n_chunks = covered = 0
    active = set(range(8))  # each worker claims until *its* queue is done
    while active:
        for w in sorted(active):
            c = source.claim(worker=w)
            if c is None:
                active.discard(w)
                continue
            covered += c.size
            source.report(c, elapsed=1e-6 * c.size)  # feeds adaptive weights
            n_chunks += 1
    kind = type(source).__name__
    print(f"{spec.technique:6s} -> {kind:22s} {n_chunks:4d} chunks, {covered} iters")
